"""Kernel dispatch layer: one policy object routes every hot-path op.

The UNet's compute hot spots each exist twice in this repo — a pure-JAX
reference (materializing, CPU-friendly, the stats oracle) and a blocked
Pallas kernel (the paper's dataflow: the SAS never leaves on-chip memory,
the FFN runs the DBSC integer datapath).  ``KernelPolicy`` names which
implementation each op uses; the dispatch functions below are the single
call sites the model layers go through, so serving, benchmarks and tests
select reference vs. fused per-op with one config knob instead of scattered
``use_*_kernel`` flags and inline imports.

Ops and implementations (``DISPATCH_TABLE``):

  self_attention   reference | fused    PSSA-pruned self-attention + stats
  cross_attention  reference | fused    text cross-attention + TIPS CAS
  ffn              reference | dbsc     GEGLU FFN (TIPS mixed precision)
  bitmap           reference | kernel   PSXU bitmap / patch-XOR / popcount
  reuse            reference | kernel   temporal-reuse patch-delta bitmap

``interpret=None`` (the default) resolves per backend at trace time —
interpret mode only where Pallas has no real lowering (CPU) — so the same
policy object is TPU-real and CPU-testable.  The stats-parity contract
(DESIGN.md §5): for any policy, reported ``PSSAStats``/TIPS ratios are
bit-identical to the reference path, because every implementation reduces
to the same integer counters before the shared byte arithmetic.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import attention, tips
from repro.kernels.bitslice_matmul.ops import bitslice_matmul
from repro.kernels.patch_bitmap.ops import patch_bitmap as _patch_bitmap_op
from repro.kernels.patch_reuse.ops import patch_delta as _patch_delta_op
from repro.kernels.runtime import resolve_interpret

_CHOICES = {
    "self_attention": ("reference", "fused"),
    "cross_attention": ("reference", "fused"),
    "ffn": ("reference", "dbsc"),
    "bitmap": ("reference", "kernel"),
    "reuse": ("reference", "kernel"),
}
_PRESETS = ("reference", "fused", "auto", "autotuned")
_FFN_QUANT = ("model", "int8")

# op -> the KernelPolicy block fields its kernels consume (also the knob
# names the autotune table stores — kept identical on purpose)
_OP_KNOBS = {
    "self_attention": ("attn_block_q", "attn_block_k"),
    "cross_attention": ("cross_block_q",),
    "bitmap": ("bitmap_block_rows",),
    "reuse": ("reuse_block_patches",),
}


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Which implementation each hot-path op dispatches to.

    Frozen + hashable so it can live inside ``UNetConfig`` and flow through
    jit closures.  ``interpret=None`` auto-selects per backend; block sizes
    are forwarded to the Pallas wrappers (which pad-and-slice, so any
    geometry is legal).
    """
    self_attention: str = "reference"
    cross_attention: str = "reference"
    ffn: str = "reference"
    bitmap: str = "reference"
    reuse: str = "reference"
    interpret: bool | None = None
    attn_block_q: int = 128
    attn_block_k: int = 128
    cross_block_q: int = 128
    bitmap_block_rows: int = 64
    reuse_block_patches: int = 8
    # tuned=True: override the block fields above with the committed
    # autotune table's winners, looked up per (backend, op, geometry) AT
    # TRACE TIME from the static operand shapes (kernels.autotune).  The
    # table never joins an executable cache key — only this bool does —
    # so swapping tables cannot cause retracing churn.
    tuned: bool = False
    # ffn_quant="int8": the DBSC route's integer matmuls run as real
    # int8 x int8 -> int32 ``lax.dot_general`` (MXU/dp4a-mappable)
    # instead of the int32 simulation; integers are bit-identical.
    ffn_quant: str = "model"

    def __post_init__(self):
        for op, allowed in _CHOICES.items():
            val = getattr(self, op)
            if val not in allowed:
                raise ValueError(
                    f"KernelPolicy.{op}={val!r}: expected one of {allowed}")
        if self.ffn_quant not in _FFN_QUANT:
            raise ValueError(
                f"KernelPolicy.ffn_quant={self.ffn_quant!r}: expected one "
                f"of {_FFN_QUANT}")

    # -- presets ---------------------------------------------------------
    @classmethod
    def reference(cls) -> "KernelPolicy":
        """Pure-JAX everywhere (the seed's materializing path)."""
        return cls()

    @classmethod
    def fused(cls) -> "KernelPolicy":
        """Blocked Pallas attention (self + cross) + PSXU kernel: neither
        the SAS nor the cross-attention probability tensor ever hits HBM.

        The FFN stays on the float reference — the DBSC integer datapath is
        a *precision* feature (INT12/INT6), selected per-op via ``ffn``
        (or the legacy ``UNetConfig.use_dbsc_kernel``), not a prerequisite
        of the fused memory path.
        """
        return cls(self_attention="fused", cross_attention="fused",
                   bitmap="kernel", reuse="kernel")

    @classmethod
    def auto(cls) -> "KernelPolicy":
        """Backend-aware default: fused where Pallas compiles, reference
        where it would only interpret.

        On CPU the fused kernels run through the Pallas interpreter, which
        is SLOWER than the materializing XLA reference (the PR 4 serving
        note measured the interpret-mode cross-attention kernel at 0.76x
        reference wall-clock) — so interpret backends keep the reference
        implementations and compiled backends get ``fused()``.  Stats are
        bit-identical either way (DESIGN.md §5), so the choice is pure
        wall time; this is what the CLIs default to.
        """
        return cls.fused() if not resolve_interpret(None) else cls.reference()

    @classmethod
    def autotuned(cls) -> "KernelPolicy":
        """``fused()`` with the committed autotune table's block winners.

        Block sizes come from ``kernels.autotune``'s per-(backend, op,
        geometry) lookup at trace time; geometries the table has never
        seen silently keep the defaults, so this preset is always safe to
        select.  Routing (which impl runs) is identical to ``fused()`` —
        only block shapes differ, and stats/counters are block-invariant.
        """
        return cls(self_attention="fused", cross_attention="fused",
                   bitmap="kernel", reuse="kernel", tuned=True)

    @classmethod
    def parse(cls, spec: str) -> "KernelPolicy":
        """Build a policy from a CLI spec.

        ``spec`` is a preset name (``reference`` | ``fused`` | ``auto`` |
        ``autotuned`` — ``auto`` resolved from the backend at parse time)
        or a comma-separated list of ``op=impl`` /
        ``interpret={auto,true,false}`` / ``tuned={true,false}`` /
        ``ffn_quant={model,int8}`` overrides applied on top of the
        reference preset, e.g. ``"self_attention=fused,ffn=dbsc"``.
        """
        spec = spec.strip()
        if spec in _PRESETS:
            return getattr(cls, spec)()
        fields = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            if "=" not in item:
                raise ValueError(
                    f"kernel policy spec {item!r}: expected op=impl or a "
                    f"preset in {_PRESETS}")
            op, impl = (s.strip() for s in item.split("=", 1))
            if op == "interpret":
                try:
                    fields[op] = {"auto": None, "true": True,
                                  "false": False}[impl.lower()]
                except KeyError:
                    raise ValueError(
                        f"kernel policy spec: interpret={impl!r} (expected "
                        f"auto, true or false)") from None
            elif op == "tuned":
                try:
                    fields[op] = {"true": True, "false": False}[impl.lower()]
                except KeyError:
                    raise ValueError(
                        f"kernel policy spec: tuned={impl!r} (expected "
                        f"true or false)") from None
            elif op == "ffn_quant" or op in _CHOICES:
                fields[op] = impl
            else:
                raise ValueError(f"kernel policy spec: unknown op {op!r} "
                                 f"(expected {tuple(_CHOICES)})")
        return cls(**fields)

    # -- views -----------------------------------------------------------
    def resolve_interpret(self) -> bool:
        return resolve_interpret(self.interpret)

    def describe(self) -> dict:
        """JSON-friendly view for serving metrics / benchmark records."""
        return {**{op: getattr(self, op) for op in _CHOICES},
                "interpret": ("auto" if self.interpret is None
                              else self.interpret),
                "interpret_resolved": self.resolve_interpret(),
                "backend": jax.default_backend(),
                "tuned": self.tuned,
                "ffn_quant": self.ffn_quant}


# ----------------------------------------------------------------------------
# Autotuned block resolution
# ----------------------------------------------------------------------------
def _blocks(policy: KernelPolicy, op: str, geom: tuple) -> dict:
    """Resolved block sizes for one dispatch call.

    Policy defaults, overridden by the committed autotune table's winner
    for this exact (backend, op, geometry) when ``policy.tuned`` — a
    TRACE-TIME lookup from static shapes (``geom`` is built from
    ``.shape`` tuples, never traced values), so the table feeds plain
    block arguments and only the hashable policy reaches cache keys.
    """
    blocks = {name: getattr(policy, name) for name in _OP_KNOBS[op]}
    if policy.tuned:
        from repro.kernels import autotune     # lazy: autotune imports ops
        won = autotune.lookup(op, geom)
        if won:
            blocks.update(won)
    return blocks


# ----------------------------------------------------------------------------
# Dispatch targets
# ----------------------------------------------------------------------------
def _ffn_mid_covered(precision, important):
    """Whether the TIPS mask also covers the second FFN matmul (ff_out)."""
    return (important is not None and precision is not None
            and precision.ffn_mid)


def _ffn_reference(policy: KernelPolicy, hn, p, important, precision=None):
    """GEGLU FFN, float matmuls; TIPS rows fake-quantized on entry.

    With ``precision.ffn_mid`` the mid activations (GEGLU output) of
    unimportant rows also round-trip the INT6 grid before the second
    matmul — the paper's "INT12 through the whole following FFN stack"
    coverage.
    """
    if important is not None:
        hn = tips.apply_precision_mask(hn, important)
    gu = jnp.einsum("btc,cd->btd", hn, p["ff_geglu"]["w"]) \
        + p["ff_geglu"]["b"]
    g, u = jnp.split(gu, 2, axis=-1)
    mid = jax.nn.gelu(g) * u
    if _ffn_mid_covered(precision, important):
        mid = tips.apply_precision_mask(mid, important)
    return jnp.einsum("btd,dc->btc", mid,
                      p["ff_out"]["w"]) + p["ff_out"]["b"]


def _ffn_dbsc(policy: KernelPolicy, hn, p, important, precision=None):
    """Both FFN matmuls through the DBSC bit-slice integer datapath.

    ``precision.ffn_mid`` extends the TIPS row mask to the second matmul:
    unimportant rows' mid activations enter the bit-slice PEs on the INT6
    grid (low 6 bits dropped on the shared scale), matching the
    reference's mid-activation fake-quant and the ledger's
    ``LedgerOptions.tips_mid`` MAC split.

    ``policy.ffn_quant`` picks the execution of those integer matmuls:
    ``model`` (the int32 simulation) or ``int8`` (real int8 x int8 ->
    int32 ``lax.dot_general``) — bit-identical accumulators either way,
    so routing never moves a counter or the energy ledger.
    """
    b, t, c = hn.shape
    bt = b * t
    imp_flat = important.reshape(bt) if important is not None else None
    gu = bitslice_matmul(hn.reshape(bt, c), p["ff_geglu"]["w"],
                         important=imp_flat,
                         interpret=policy.interpret,
                         quant_path=policy.ffn_quant).reshape(b, t, -1) \
        + p["ff_geglu"]["b"]
    g, u = jnp.split(gu, 2, axis=-1)
    mid = jax.nn.gelu(g) * u
    mid_imp = imp_flat if _ffn_mid_covered(precision, important) else None
    return bitslice_matmul(mid.reshape(bt, mid.shape[-1]), p["ff_out"]["w"],
                           important=mid_imp,
                           interpret=policy.interpret,
                           quant_path=policy.ffn_quant).reshape(b, t, c) \
        + p["ff_out"]["b"]


DISPATCH_TABLE = {
    "self_attention": {
        "reference": attention.self_attention_pssa,
        "fused": attention.self_attention_pssa_fused,
    },
    "cross_attention": {
        "reference": attention.cross_attention_tips,
        "fused": attention.cross_attention_tips_fused,
    },
    "ffn": {
        "reference": _ffn_reference,
        "dbsc": _ffn_dbsc,
    },
    "bitmap": {
        "reference": functools.partial(_patch_bitmap_op, use_kernel=False),
        "kernel": _patch_bitmap_op,
    },
    "reuse": {
        "reference": functools.partial(_patch_delta_op, use_kernel=False),
        "kernel": _patch_delta_op,
    },
}


# ----------------------------------------------------------------------------
# Dispatch entry points (the call sites model layers use)
# ----------------------------------------------------------------------------
def self_attention(policy: KernelPolicy, q, k, v, *, patch: int,
                   threshold, prune_scores: bool = True,
                   stats_rows: int | None = None,
                   reference_stats: bool = False,
                   row_stats: bool = False) -> attention.SelfAttnOut:
    """PSSA self-attention via the policy's implementation.

    Three combinations force the materializing reference regardless of
    policy: ``reference_stats`` (the seed's stats oracle, definitionally
    materializing), ``prune_scores=False`` (the paper-baseline ablation
    keeps sub-threshold scores in the value matmul; the fused kernel always
    prunes), and a PER-ROW ``threshold`` array (phase-scheduled sampling —
    the Pallas kernel bakes its scalar threshold into the kernel closure,
    so per-row thresholds take the broadcast-friendly reference; the
    support restriction is documented in DESIGN.md §10).  ``row_stats``
    reports per-row integer counters (``pssa.PSSARowCounters``) instead of
    folded byte stats — identical counters either way, so the
    slot-serving ledger stays bit-exact across implementations.
    """
    impl = policy.self_attention
    per_row_threshold = getattr(threshold, "ndim", 0) >= 1
    if impl == "fused" and (reference_stats or not prune_scores
                            or per_row_threshold):
        impl = "reference"
    if impl == "fused":
        blk = _blocks(policy, "self_attention", (*q.shape, patch))
        return attention.self_attention_pssa_fused(
            q, k, v, patch=patch, threshold=threshold,
            stats_rows=stats_rows, interpret=policy.interpret,
            bq=blk["attn_block_q"], bk=blk["attn_block_k"],
            row_stats=row_stats)
    return attention.self_attention_pssa(
        q, k, v, patch=patch, threshold=threshold,
        prune_scores=prune_scores, stats_rows=stats_rows,
        reference_stats=reference_stats, row_stats=row_stats)


def cross_attention(policy: KernelPolicy, q, k_text, v_text, *,
                    precision, stats_rows: int | None = None,
                    row_stats: bool = False,
                    threshold_scale=None) -> attention.CrossAttnOut:
    """Cross-attention + TIPS spotting via the policy's implementation.

    ``precision`` (a ``core.precision.PrecisionPolicy``) drives the
    spotting mode; it runs on the head-averaged CAS identically for both
    implementations, so routing never changes a precision decision (the
    importance mask / low ratio / ledger terms are bit-identical across
    ``reference`` and ``fused`` — DESIGN.md §7).  ``row_stats`` reports
    per-row important-token counts (``tips.TIPSRowCounters``).
    ``threshold_scale`` ((B,) or None) scales each row's spotting
    threshold (phase-scheduled sampling) — it lives downstream of both
    kernels, in the shared spotting tail, so either implementation
    honours it identically.
    """
    if policy.cross_attention == "fused":
        blk = _blocks(policy, "cross_attention",
                      (*q.shape, k_text.shape[2]))
        return attention.cross_attention_tips_fused(
            q, k_text, v_text, precision=precision, stats_rows=stats_rows,
            interpret=policy.interpret, bq=blk["cross_block_q"],
            row_stats=row_stats, threshold_scale=threshold_scale)
    return attention.cross_attention_tips(
        q, k_text, v_text, precision=precision, stats_rows=stats_rows,
        row_stats=row_stats, threshold_scale=threshold_scale)


def ffn_geglu(policy: KernelPolicy, hn, p, important, precision=None):
    """(B, T, C) normed hidden -> (B, T, C) FFN output (pre-residual).

    ``p`` carries ``ff_geglu``/``ff_out`` weights; ``important`` is the
    TIPS row mask (None -> all rows full precision); ``precision`` (a
    ``PrecisionPolicy``) extends the mask to the second matmul when its
    ``ffn_mid`` flag is set.
    """
    return DISPATCH_TABLE["ffn"][policy.ffn](policy, hn, p, important,
                                             precision)


def patch_bitmap(policy: KernelPolicy, sas, patch: int, threshold: float):
    """PSXU payload op: packed XOR bitmap + per-patch popcounts."""
    if policy.bitmap == "kernel":
        tk = sas.shape[-1]
        rows = sas.size // tk
        blk = _blocks(policy, "bitmap", (rows, tk, patch))
        return _patch_bitmap_op(sas, patch, threshold, use_kernel=True,
                                interpret=policy.interpret,
                                br=blk["bitmap_block_rows"])
    return _patch_bitmap_op(sas, patch, threshold, use_kernel=False)


def patch_delta(policy: KernelPolicy, x, x_ref, *, patch: int,
                threshold: float):
    """Temporal-reuse change detection via the policy's implementation.

    (B, T, C) tokens vs cached reference -> ((B, P) float32 max-abs patch
    delta, (B, P) bool active bitmap).  Reference and kernel reduce max
    over the same values (exactly commutative), so the bitmap — and every
    reuse counter downstream of it — is bit-identical across routing.
    """
    if policy.reuse == "kernel":
        blk = _blocks(policy, "reuse", (*x.shape, patch))
        return _patch_delta_op(x, x_ref, patch=patch, threshold=threshold,
                               use_kernel=True, interpret=policy.interpret,
                               bp=blk["reuse_block_patches"])
    return _patch_delta_op(x, x_ref, patch=patch, threshold=threshold,
                           use_kernel=False)


def support_matrix() -> list:
    """op x impl support rows (README kernel-support matrix source)."""
    rows = []
    for op, impls in DISPATCH_TABLE.items():
        for impl in impls:
            pallas = impl not in ("reference",)
            rows.append({
                "op": op, "impl": impl,
                "pallas": pallas,
                "cpu": "interpret" if pallas else "native",
                "tpu": "compiled" if pallas else "native (XLA)",
            })
    return rows
