"""Shared runtime policy helpers for the Pallas op wrappers.

Three concerns every ``ops.py`` wrapper (and the autotuner) has in common:

* **interpret selection** — the kernels must run in Pallas interpret mode on
  CPU (the test/CI container) and compiled on a real accelerator.  The seed
  wrappers hardcoded ``interpret=True``, which made the "TPU-native" path
  permanently interpreted.  ``resolve_interpret(None)`` derives the right
  value from ``jax.default_backend()`` at trace time, so the same call site
  is TPU-real and CPU-testable.
* **block padding** — grids need block-divisible extents.  The seed
  fallback (``while t % blk: blk //= 2``) collapses to degenerate 1-wide
  blocks for non-power-of-two extents; ``pad_axis_to`` pads the operand up
  to the block multiple instead (callers slice the result back), matching
  what ``bitslice_matmul/ops.py`` always did.
* **min-of-k wall-clock** — the block autotuner (``kernels.autotune``) and
  every bench time jitted callables the same way: warm up outside the
  clock, then take the MINIMUM of k block-until-ready repetitions (one
  implementation here; ``benchmarks/timing.py`` re-exports it for the
  bench tree).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


# backends with a real Pallas lowering: Mosaic on TPU, triton-pallas on
# GPU (jax.default_backend() has reported the CUDA platform as "gpu"
# historically and "cuda" in newer releases; ROCm reports "rocm")
COMPILING_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def default_interpret() -> bool:
    """Pallas interpret mode iff the default backend has no real lowering.

    TPU compiles via Mosaic and GPU via triton-pallas, so both run the
    kernels natively; only backends without a Pallas lowering (CPU — the
    test/CI container) fall back to the interpreter.  (The seed treated
    TPU as the only compiling backend, which forced interpret mode — and
    with it ``KernelPolicy.auto()``'s reference routing — on GPU.)
    """
    return jax.default_backend() not in COMPILING_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> backend-derived default; explicit values pass through."""
    return default_interpret() if interpret is None else bool(interpret)


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def pad_axis_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple of ``mult`` (no-op if even)."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def timed(fn, *args, reps: int = 3, warmup: int = 1):
    """(last output, min wall seconds) of ``fn(*args)`` over ``reps``.

    ``warmup`` un-timed calls run first (the first one compiles); each
    timed call is bracketed by ``jax.block_until_ready`` so async
    dispatch never masquerades as execution.  Min — not mean — because
    the quantity under test is the compiled program's cost: everything
    that inflates a sample (GC, another process, lazy page-in) is
    one-sided noise, and a single post-compile sample drifts with
    machine warm-up across a sweep, biasing cross-config ratios.
    """
    out = None
    for _ in range(max(1, warmup)):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def min_wall_s(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Just the min wall seconds of ``timed`` (drop the output)."""
    return timed(fn, *args, reps=reps, warmup=warmup)[1]


def min_over(reps: int, sample) -> float:
    """Min of ``reps`` calls to ``sample()`` (a wall-seconds thunk).

    For callables that carry their own clock (e.g. the engine's
    ``last_wall_s``) where ``timed`` cannot bracket the work itself.
    """
    return min(sample() for _ in range(max(1, reps)))
