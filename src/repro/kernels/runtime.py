"""Shared runtime policy helpers for the Pallas op wrappers.

Two concerns every ``ops.py`` wrapper has in common:

* **interpret selection** — the kernels must run in Pallas interpret mode on
  CPU (the test/CI container) and compiled on a real accelerator.  The seed
  wrappers hardcoded ``interpret=True``, which made the "TPU-native" path
  permanently interpreted.  ``resolve_interpret(None)`` derives the right
  value from ``jax.default_backend()`` at trace time, so the same call site
  is TPU-real and CPU-testable.
* **block padding** — grids need block-divisible extents.  The seed
  fallback (``while t % blk: blk //= 2``) collapses to degenerate 1-wide
  blocks for non-power-of-two extents; ``pad_axis_to`` pads the operand up
  to the block multiple instead (callers slice the result back), matching
  what ``bitslice_matmul/ops.py`` always did.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Pallas interpret mode iff the default backend has no real lowering.

    These kernels are written against the TPU lowering (pltpu memory
    spaces, MXU-shaped blocks), so every other backend — CPU *and* GPU —
    runs the interpreter; only TPU compiles.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> backend-derived default; explicit values pass through."""
    return default_interpret() if interpret is None else bool(interpret)


def round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def pad_axis_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple of ``mult`` (no-op if even)."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
