"""Fused Mamba-2 SSD scan Pallas kernel (beyond-paper, §Perf cell A lesson).

The pure-jnp SSD (models/ssm.py) spills every intermediate of the chunked
algorithm to HBM — decay tensors, per-chunk states, masked segment sums —
which iteration A2 measured as the dominant memory term of mamba2 training.
This kernel fuses ONE (batch*head, chunk) tile's whole pipeline in VMEM:

  grid = (BH, T/chunk) with the chunk axis iterated sequentially; the
  recurrent state (p, n) lives in a VMEM scratch carried across chunk steps
  (the standard TPU sequential-grid carry pattern), zero-initialized when a
  new (batch, head) row begins.

  per tile:  dAc    = cumsum(dA)                       (l,)
             L      = exp(segsum(dA)) (masked tril)    (l, l)
             y_diag = ((C B^T) ∘ L) @ x                (l, p)   [MXU]
             y_off  = (C ∘ exp(dAc)) @ state^T         (l, p)   [MXU]
             state  = exp(dAc[-1] - dAc)-weighted B^T @ x
                      + exp(dAc[-1]) * state                    [MXU]

Only x, dA, B, C tiles stream in and y tiles stream out — the decay
tensors never touch HBM.  VMEM bound per tile: l*(2n + 2p) + l*l + p*n
floats (chunk 128, p 64, n 128: ~180 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, da_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr,
            *, nchunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0]                                   # (l, p) float32
    dA = da_ref[0]                                 # (l,)
    B = b_ref[0]                                   # (l, n)
    C = c_ref[0]                                   # (l, n)
    l = x.shape[0]

    dAc = jnp.cumsum(dA)                           # (l,)
    # segment sums: seg[i, j] = dAc[i] - dAc[j] for i >= j (decay j -> i)
    seg = dAc[:, None] - dAc[None, :]
    mask = jnp.arange(l)[:, None] >= jnp.arange(l)[None, :]
    L = jnp.where(mask, jnp.exp(seg), 0.0)         # (l, l)

    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    y_diag = jnp.dot(scores * L, x, preferred_element_type=jnp.float32)

    state = state_scr[...]                         # (p, n)
    decay_in = jnp.exp(dAc)[:, None]               # (l, 1)
    y_off = jnp.dot(C * decay_in, state.T,
                    preferred_element_type=jnp.float32)

    y_ref[0] = y_diag + y_off

    # state update: decay each position to the chunk end, inject, carry
    decay_to_end = jnp.exp(dAc[-1] - dAc)[:, None]  # (l, 1)
    inject = jnp.dot(x.T, B * decay_to_end,
                     preferred_element_type=jnp.float32)      # (p, n)
    new_state = jnp.exp(dAc[-1]) * state + inject
    state_scr[...] = new_state

    @pl.when(c_idx == nchunks - 1)
    def _emit():
        state_out_ref[0] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x, dA, B, C, chunk: int = 128, interpret: bool | None = None):
    """Fused SSD over folded heads.

    x (BH, T, p) float32 — pre-multiplied by dt;
    dA (BH, T) float32 — dt * A (negative reals);
    B, C (BH, T, n) float32.
    Returns (y (BH, T, p), final_state (BH, p, n)).
    """
    bh, t, p = x.shape
    n = B.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nchunks = t // chunk

    y, state = pl.pallas_call(
        functools.partial(_kernel, nchunks=nchunks),
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, p, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, dA, B, C)
    return y, state
