from repro.kernels.ssd_scan.ops import ssd_scan_fused  # noqa: F401
