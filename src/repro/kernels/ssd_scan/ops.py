"""Public op: fused SSD scan in the model's (B, T, H, P) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel",
                                             "interpret"))
def ssd_scan_fused(x, dt, A, B, C, chunk: int = 128,
                   use_kernel: bool = True, interpret: bool | None = None):
    """Drop-in for models.ssm.ssd_scan (single B/C group).

    x (b,t,h,p); dt (b,t,h) post-softplus; A (h,)<0; B,C (b,t,n).
    Returns (y (b,t,h,p), final_state (b,h,p,n)).
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    ck = min(chunk, t)
    while t % ck:
        ck //= 2

    xdt = (x * dt[..., None]).astype(jnp.float32)
    dA = (dt * A[None, None, :]).astype(jnp.float32)
    # fold heads: (b,t,h,p) -> (b*h, t, p); B/C broadcast over heads
    xf = jnp.moveaxis(xdt, 2, 1).reshape(b * h, t, p)
    dAf = jnp.moveaxis(dA, 2, 1).reshape(b * h, t)
    Bf = jnp.broadcast_to(B[:, None], (b, h, t, n)).reshape(b * h, t, n)
    Cf = jnp.broadcast_to(C[:, None], (b, h, t, n)).reshape(b * h, t, n)
    Bf = Bf.astype(jnp.float32)
    Cf = Cf.astype(jnp.float32)

    fn = ssd_scan_kernel if use_kernel else ssd_scan_ref
    if use_kernel:
        y, state = fn(xf, dAf, Bf, Cf, chunk=ck, interpret=interpret)
    else:
        y, state = fn(xf, dAf, Bf, Cf, chunk=ck)
    y = jnp.moveaxis(y.reshape(b, h, t, p), 1, 2)
    return y, state.reshape(b, h, p, n)
