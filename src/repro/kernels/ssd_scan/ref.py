"""Pure-jnp oracle for the fused SSD kernel (folded-head layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dA, B, C, chunk: int = 128):
    """Same contract as ssd_scan_kernel: x (BH,T,p) pre-multiplied by dt,
    dA (BH,T), B/C (BH,T,n) -> (y (BH,T,p), state (BH,p,n)).

    Direct sequential recurrence — the textbook SSM semantics:
        s_t = exp(dA_t) * s_{t-1} + x_t^T B_t      (p, n)
        y_t = s_t C_t^T                            (p,)
    """
    bh, t, p = x.shape
    n = B.shape[-1]

    def per_head(xh, dAh, Bh, Ch):
        def step(s, inp):
            xt, dat, bt, ct = inp
            s = jnp.exp(dat) * s + jnp.outer(xt, bt)
            return s, s @ ct
        s0 = jnp.zeros((p, n))
        state, ys = jax.lax.scan(step, s0, (xh, dAh, Bh, Ch))
        return ys, state

    y, state = jax.vmap(per_head)(x, dA, B, C)
    return y, state
