# Pallas TPU kernels for the paper's compute hot-spots:
#   bitslice_matmul — DBSC dual-mode bit-slice core (§IV-B)
#   pssa_attention  — blocked self-attention with threshold score pruning
#                     + kernel-side PSSA byte counters (§III)
#   patch_bitmap    — PSXU bitmap generate + patch-XOR + popcount (§III-B)
# Each package ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with pad-and-slice block handling) and ref.py (pure-jnp oracle).
#
# dispatch.py — the KernelPolicy dispatch layer: one policy object routes
#   every hot-path op to its reference or Pallas implementation (DESIGN.md
#   §5).  runtime.py — shared interpret auto-selection (interpret only
#   where Pallas has no real lowering) and padding helpers.
