# Pallas TPU kernels for the paper's compute hot-spots:
#   bitslice_matmul — DBSC dual-mode bit-slice core (§IV-B)
#   pssa_attention  — blocked self-attention with threshold score pruning (§III)
#   patch_bitmap    — PSXU bitmap generate + patch-XOR + popcount (§III-B)
# Each package ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper) and ref.py (pure-jnp oracle).  Validated with interpret=True.
