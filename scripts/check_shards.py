#!/usr/bin/env python
"""Tier-1 test sharding: single source of truth + collection-drift guard.

CI runs the tier-1 suite as two parallel pytest jobs (the known balanced
chunk split).  The shard file lists live HERE — the workflow asks this
script for them (``--files A``), so the split cannot silently diverge
between jobs.  ``--verify`` is the drift guard: it collects the full suite
and each shard with ``pytest --collect-only`` and fails unless the shard
union EQUALS the full collection (a file listed twice, or a shard test
missing from the full collection, breaks the build instead of silently
skipping tests).

A NEW ``tests/test_*.py`` file needs no manual shard bump: any test file
on disk that appears in no hand-curated list is auto-assigned
deterministically (fewest-files shard first, alphabetical everywhere) by
``_effective_shards()``, and both ``--files`` and ``--verify`` operate on
the effective assignment — the two CI jobs recompute the identical split
from the same directory listing.

Usage:
  python scripts/check_shards.py --files A      # print shard A's files
  python scripts/check_shards.py --verify       # collection-drift guard
"""
from __future__ import annotations

import argparse
import glob
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the balanced two-way split (roughly equal wall time on a 2-core runner);
# files NOT listed here are auto-assigned by _effective_shards()
SHARDS = {
    "A": [
        "tests/test_archs.py",
        "tests/test_system.py",
        "tests/test_train_infra.py",
        "tests/test_perf_features.py",
        "tests/test_ssd_kernel.py",
        "tests/test_sharded_engine.py",
        "tests/test_continuous.py",
        "tests/test_serving.py",
    ],
    "B": [
        "tests/test_diffusion.py",
        "tests/test_engine.py",
        "tests/test_dispatch.py",
        "tests/test_precision.py",
        "tests/test_kernels.py",
        "tests/test_pssa.py",
        "tests/test_tips_quant.py",
        "tests/test_ledger_properties.py",
    ],
}


def _effective_shards() -> dict:
    """The curated split plus deterministic auto-assignment of new files.

    Every ``tests/test_*.py`` on disk that no curated list names is
    appended to the shard with the fewest files at that moment
    (alphabetical shard-name tiebreak), in alphabetical file order — a
    pure function of the directory listing, so parallel CI jobs agree on
    the split without a manual SHARDS bump.  Curated entries whose file
    vanished are dropped (the file's tests are gone from the full
    collection too, so --verify stays green across deletions).
    """
    on_disk = sorted(
        os.path.relpath(p, ROOT).replace(os.sep, "/")
        for p in glob.glob(os.path.join(ROOT, "tests", "test_*.py")))
    listed = {f for files in SHARDS.values() for f in files}
    eff = {name: [f for f in files if f in set(on_disk)]
           for name, files in SHARDS.items()}
    auto = {}
    for f in on_disk:
        if f in listed:
            continue
        name = min(sorted(eff), key=lambda n: len(eff[n]))
        eff[name].append(f)
        auto[f] = name
    return {"shards": eff, "auto": auto}


def _collect(args: list) -> set:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "--no-header", *args],
        cwd=ROOT, env=env, capture_output=True, text=True)
    if r.returncode not in (0, 5):          # 5 = no tests collected
        print(r.stdout + r.stderr, file=sys.stderr)
        raise SystemExit(f"pytest --collect-only {args} failed "
                         f"({r.returncode})")
    return {line.strip() for line in r.stdout.splitlines()
            if "::" in line and not line.startswith(("=", "warning"))}


def verify() -> int:
    eff = _effective_shards()
    for f, name in sorted(eff["auto"].items()):
        print(f"auto-assigned {f} -> shard {name}")
    full = _collect([])
    union: set = set()
    overlap_ok = True
    for name, files in eff["shards"].items():
        got = _collect(files)
        dup = union & got
        if dup:
            overlap_ok = False
            print(f"shard {name} overlaps another shard on "
                  f"{len(dup)} test(s), e.g. {sorted(dup)[:3]}")
        union |= got
        print(f"shard {name}: {len(got)} tests from {len(files)} files")
    missing = full - union
    extra = union - full
    print(f"full collection: {len(full)} tests; shard union: {len(union)}")
    if missing:
        print(f"COLLECTION DRIFT: {len(missing)} test(s) in no shard "
              f"(tests collected outside tests/test_*.py? check "
              f"scripts/check_shards.py):")
        for t in sorted(missing)[:20]:
            print(f"  - {t}")
    if extra:
        print(f"COLLECTION DRIFT: {len(extra)} shard test(s) not in the "
              f"full collection:")
        for t in sorted(extra)[:20]:
            print(f"  - {t}")
    if missing or extra or not overlap_ok:
        return 1
    print("shard union == full collection; shards disjoint — ok")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--files", choices=sorted(SHARDS),
                   help="print the given shard's file list (one line)")
    g.add_argument("--verify", action="store_true",
                   help="fail unless the shard union equals the full "
                        "pytest collection and shards are disjoint")
    args = ap.parse_args()
    if args.files:
        eff = _effective_shards()
        for f, name in sorted(eff["auto"].items()):
            if name == args.files:
                print(f"auto-assigned {f} -> shard {name}",
                      file=sys.stderr)
        print(" ".join(eff["shards"][args.files]))
        raise SystemExit(0)
    raise SystemExit(verify())


if __name__ == "__main__":
    main()
